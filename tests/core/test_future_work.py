"""Paper §10.3 future-work items, implemented and tested:
prefill-decode disaggregation, speculative decoding in P(b), adaptive
topology control."""
import numpy as np
import pytest

from repro.core import AZURE, H100_LLAMA70B, FleetOpt, computed_profile
from repro.core.adaptive import AdaptiveController
from repro.core.disagg import Disaggregated
from repro.core.hardware import H100
from repro.core.modelspec import LLAMA31_8B, LLAMA31_70B
from repro.core.power import H100_POWER
from repro.core.speculative import speculative_tok_per_watt, sweep
from repro.core.workloads import AGENT, AZURE


def test_disagg_energy_economics():
    """Beyond-paper finding that *contradicts* the paper's §10.3 hope:
    under output-only tok/W accounting, prefill-decode disaggregation
    LOSES to interleaved FleetOpt — the dedicated prefill fleet runs
    compute-saturated (~P_nom) watts that chunked-prefill interleaving
    absorbed for free inside memory-bound decode bubbles.  Disaggregation
    only looks better if prefill energy is excluded from the denominator
    (which is an accounting choice, not a saving).  Splitwise optimizes
    latency isolation, not energy."""
    fo = FleetOpt(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    dis = Disaggregated(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    assert dis.tokens_per_s == pytest.approx(fo.tokens_per_s, rel=0.05)
    decode_inst = sum(p.instances for p in dis.pools
                      if p.name.startswith("decode"))
    assert decode_inst < fo.instances          # decode fleet shrinks...
    assert dis.tok_per_watt < fo.tok_per_watt  # ...but whole-fleet tok/W drops
    # decode-side-only accounting (prefill excluded): better than fo
    dec_pools = [p for p in dis.pools if p.name.startswith("decode")]
    dec_tpw = (sum(p.tokens_per_s for p in dec_pools)
               / sum(p.instances * p.power_w_per_instance
                     for p in dec_pools))
    assert dec_tpw > fo.tok_per_watt


def test_disagg_kv_handoff_is_ici_feasible():
    # TP degree comes from the profile (the old helper hardcoded tp=8)
    bps = Disaggregated.kv_handoff_bytes_per_s(AZURE, LLAMA31_70B,
                                               H100_LLAMA70B)
    # ~1000 req/s * ~1.6K tokens * 328KB/token ~ 0.5 TB/s across the fleet;
    # tens of instances * 450 GB/s links: feasible, but not free
    assert 1e11 < bps < 2e12
    # whole-instance KV is TP-invariant while TP <= n_kv (sharded GQA
    # stores ceil(n_kv/TP) heads per GPU); TP > n_kv replicates heads
    # across ranks and the migration really moves the extra copies
    prof_tp1 = computed_profile(LLAMA31_8B, H100, H100_POWER, tp=1)
    prof_tp16 = computed_profile(LLAMA31_8B, H100, H100_POWER, tp=16)
    per_req8 = Disaggregated.kv_handoff_bytes_per_request(
        1000, LLAMA31_70B, H100_LLAMA70B)
    per_req1 = Disaggregated.kv_handoff_bytes_per_request(
        1000, LLAMA31_70B, prof_tp1)
    per_req16 = Disaggregated.kv_handoff_bytes_per_request(
        1000, LLAMA31_70B, prof_tp16)
    assert per_req8 == pytest.approx(per_req1)
    assert per_req16 == pytest.approx(2 * per_req8)
    # the per-request migration latency is ms-scale on NVLink-class links
    delay = Disaggregated().kv_handoff_delay_s(1000, LLAMA31_70B,
                                               H100_LLAMA70B)
    assert 1e-4 < delay < 1e-2


def test_speculative_decoding_tradeoff():
    target = H100_LLAMA70B
    draft = computed_profile(LLAMA31_8B, H100, H100_POWER, tp=1)
    good = speculative_tok_per_watt(target, draft, accept_rate=0.8,
                                    speculation_len=4)
    bad = speculative_tok_per_watt(target, draft, accept_rate=0.5,
                                   speculation_len=8)
    assert good.tok_per_watt > bad.tok_per_watt
    assert good.tokens_per_round > 2.9          # (1-.8^5)/.2
    # the §10.3 open question answered within the model: high acceptance
    # helps, long speculation at low acceptance burns draft watts
    assert good.speedup_vs_plain > 1.0
    assert bad.speedup_vs_plain < good.speedup_vs_plain
    pts = sweep(target, draft)
    assert len(pts) == 12
    assert all(p.tok_per_watt > 0 for p in pts)


def test_adaptive_controller_tracks_distribution_shift():
    ctl = AdaptiveController(H100_LLAMA70B, LLAMA31_70B,
                             reoptimize_every=2000, capacity=4000, seed=1)
    rng = np.random.default_rng(0)
    # phase 1: chat-like traffic (short)
    idx = rng.integers(0, 200_000, 3000)
    for p, o in zip(AZURE.prompts[idx], AZURE.outputs[idx]):
        ctl.observe(int(p), int(o))
    b_chat = ctl.history[-1]["b_short"] if ctl.history else ctl.b_short
    # phase 2: agent-heavy traffic (long, dispersed)
    idx = rng.integers(0, 200_000, 6000)
    for p, o in zip(AGENT.prompts[idx], AGENT.outputs[idx]):
        ctl.observe(int(p), int(o))
    b_agent = ctl.history[-1]["b_short"]
    assert b_agent >= b_chat            # boundary grows with the traffic
    assert len(ctl.history) >= 2
    assert ctl.route(100, 325.0) == "short"
    assert ctl.route(60000, 325.0) == "long"
