"""Eq. 1 logistic power model vs the paper's measured/stated values."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.power import (B200_POWER, GB200_POWER, H100_POWER, H200_POWER,
                              PowerModel)
from repro.core.hardware import B200, GB200, H100, H200


# Paper Table 1 P_sat column (H100): P(n_max) at each context window.
H100_PSAT = [(512, 598), (256, 593), (128, 583), (64, 557), (32, 507),
             (16, 435), (8, 369)]


@pytest.mark.parametrize("b,expected", H100_PSAT)
def test_h100_table1_psat(b, expected):
    assert H100_POWER.power_w(b) == pytest.approx(expected, rel=0.005)


def test_h100_calibration_points():
    """Chung et al.: ~300 W at b=1, ~600 W at b=128 (3% fit error)."""
    assert H100_POWER.power_w(1) == pytest.approx(311, rel=0.03)
    assert H100_POWER.power_w(128) == pytest.approx(583, rel=0.03)


def test_half_saturation():
    """Paper: power saturates around 2^4.2 ~ 18 concurrent sequences."""
    assert H100_POWER.saturation_b() == pytest.approx(18.4, rel=0.01)
    mid = H100_POWER.power_w(H100_POWER.saturation_b())
    assert mid == pytest.approx((300 + 600) / 2, rel=0.01)


def test_tdp_fractions():
    """Appendix A: P_idle = 0.43 TDP, P_nom = 0.86 TDP for projections."""
    for chip, pm in [(H200, H200_POWER), (B200, B200_POWER),
                     (GB200, GB200_POWER)]:
        assert pm.p_idle_w == pytest.approx(0.43 * chip.tdp_w, rel=0.01)
        assert pm.p_nom_w == pytest.approx(0.86 * chip.tdp_w, rel=0.01)


def test_idle_floor():
    assert H100_POWER.power_w(0) == 300.0
    assert H100_POWER.power_w(-3) == 300.0


@settings(max_examples=50, deadline=None)
@given(b1=st.floats(0.5, 4096), b2=st.floats(0.5, 4096))
def test_monotone_in_concurrency(b1, b2):
    lo, hi = sorted([b1, b2])
    assert H100_POWER.power_w(lo) <= H100_POWER.power_w(hi) + 1e-9


@settings(max_examples=50, deadline=None)
@given(b=st.floats(0, 1e6))
def test_bounded(b):
    p = float(H100_POWER.power_w(b))
    assert 300.0 - 1e-6 <= p <= 600.0 + 1e-6


def test_from_tdp_fraction_roundtrip():
    pm = PowerModel.from_tdp_fraction(H100)
    assert pm.p_idle_w == pytest.approx(301.0, rel=0.01)
    assert pm.p_nom_w == pytest.approx(602.0, rel=0.01)
