"""TopologySpec IR: construction validation, bit-exact provision parity
with the legacy per-kind provisioners, role round-trips, registry
binding, and spec-hash stability.

Parity is asserted with `==` (not approx): `from_kind` is pinned to the
exact float op-order of the legacy classes, so every `math.ceil`
instance count is guaranteed to land identically and the committed
quick-bench baseline can never move.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.disagg import Disaggregated
from repro.core.fleet import FleetReport, PoolSizing
from repro.core.modelspec import LLAMA31_70B, QWEN3_235B_A22B
from repro.core.multipool import MultiPool, ladder_windows
from repro.core.profiles import H100_LLAMA70B
from repro.core.routing import (LONG_WINDOW, FleetOpt, Homogeneous,
                                Semantic, TwoPool)
from repro.core.topospec import (SEMANTIC_KINDS, PoolSpec, TopologySpec,
                                 plan_roles)
from repro.core.workloads import AGENT, AZURE, LMSYS

PROF = H100_LLAMA70B
MODEL = LLAMA31_70B
WORKLOADS = (AZURE, LMSYS, AGENT)


def _legacy_twin(kind, **kw):
    """The analytical provisioner the legacy `build_topology` constructed
    for each kind (its serving-twin conventions: fleetopt/disagg route
    and serve at W = int(gamma * b_short))."""
    b_short = kw.get("b_short", 4096)
    gamma = kw.get("gamma", 2.0)
    dispatch_ms = kw.get("dispatch_ms", 0.0)
    if kind == "homo":
        return Homogeneous(), PROF, MODEL
    if kind == "moe_pool":
        # reuse the spec's floored profile object: with_dispatch_floor
        # constructs a fresh (value-equal) profile on every call
        return Homogeneous(), kw["spec"].pool("moe").profile, \
            QWEN3_235B_A22B
    if kind == "two_pool":
        return TwoPool(b_short=b_short), PROF, MODEL
    if kind == "fleetopt":
        return FleetOpt(int(gamma * b_short), gamma=1.0), PROF, MODEL
    if kind == "multipool":
        return MultiPool(kw["windows"], gamma=gamma), PROF, MODEL
    if kind in SEMANTIC_KINDS:
        g = 1.0 if kind == "semantic" else gamma
        model = QWEN3_235B_A22B if kind == "moe_semantic" else MODEL
        prof = kw["spec"].pool("large").profile \
            if kind == "moe_semantic" else PROF
        spec = kw["spec"]  # reuse the spec's derived small profile/model
        return Semantic(b_short=b_short,
                        small_profile=spec.pool("small").profile,
                        small_model=spec.models["small"], gamma=g,
                        misroute_rate=kw.get("misroute_rate", 0.0)), \
            prof, model
    if kind in ("disagg", "disagg_fleetopt"):
        return Disaggregated(b_short=int(gamma * b_short), gamma=1.0,
                             split=(kind == "disagg_fleetopt")), PROF, MODEL
    raise AssertionError(kind)


_SIZED_FIELDS = ("name", "window", "arrival_rate", "mean_output",
                 "mean_context", "mean_prompt", "hol_inflation", "phase",
                 "instances", "n_active", "power_w_per_instance",
                 "tokens_per_s", "decode_bound", "prefill_bound",
                 "n_inflight", "sized_prefill_mfu")


def _assert_reports_identical(got: FleetReport, want: FleetReport):
    assert got.label == want.label
    assert len(got.pools) == len(want.pools)
    for g, w in zip(got.pools, want.pools):
        for f in _SIZED_FIELDS:
            assert getattr(g, f) == getattr(w, f), \
                (g.name, f, getattr(g, f), getattr(w, f))
        assert g.profile is w.profile, (g.name, g.profile, w.profile)


_KIND_CASES = [
    ("homo", {}),
    ("moe_pool", {"dispatch_ms": 2.0}),
    ("two_pool", {"b_short": 4096}),
    ("fleetopt", {"b_short": 4096, "gamma": 2.0}),
    ("fleetopt", {"b_short": 1536, "gamma": 3.0}),
    ("multipool", {"windows": tuple(ladder_windows(3)), "gamma": 2.0}),
    ("multipool", {"windows": (2048, 8192, 16384, 65536), "gamma": 1.5}),
    ("semantic", {"b_short": 4096}),
    ("semantic", {"b_short": 4096, "misroute_rate": 0.05}),
    ("semantic_fleetopt", {"b_short": 4096, "gamma": 2.0}),
    ("moe_semantic", {"b_short": 4096, "gamma": 2.0, "dispatch_ms": 2.0}),
    ("disagg", {}),
    ("disagg_fleetopt", {"b_short": 4096, "gamma": 2.0}),
]


@pytest.mark.parametrize("workload", WORKLOADS, ids=lambda w: w.name)
@pytest.mark.parametrize("kind,kw", _KIND_CASES,
                         ids=[f"{k}-{i}" for i, (k, _) in
                              enumerate(_KIND_CASES)])
def test_provision_parity_bit_exact(kind, kw, workload):
    model = QWEN3_235B_A22B if kind in ("moe_pool", "moe_semantic") else MODEL
    spec = TopologySpec.from_kind(kind, PROF, model, **kw)
    legacy, prof, lmodel = _legacy_twin(kind, spec=spec, **kw)
    want = legacy.provision(workload, prof, lmodel)
    got = spec.provision(workload)
    _assert_reports_identical(got, want)


# --- satellite 1: role round-trip vs the removed topology_roles table ----

def _legacy_topology_roles(kind, plan):
    """Inline copy of the deleted `serving.fleetsim.topology_roles` kind
    table (pre-refactor), kept as the round-trip oracle."""
    if kind == "homo":
        return ["homo"]
    if kind == "moe_pool":
        return ["moe"]
    if kind in ("two_pool", "fleetopt"):
        assert len(plan.pools) == 2
        return ["short", "long"]
    if kind in SEMANTIC_KINDS:
        return ["small", "large"]
    if kind in ("multipool", "disagg", "disagg_fleetopt"):
        return [p.name for p in sorted(plan.pools, key=lambda p: p.window)]
    raise ValueError(kind)


@pytest.mark.parametrize("kind,kw", _KIND_CASES,
                         ids=[f"{k}-{i}" for i, (k, _) in
                              enumerate(_KIND_CASES)])
def test_roles_round_trip_legacy_table(kind, kw):
    model = QWEN3_235B_A22B if kind in ("moe_pool", "moe_semantic") else MODEL
    spec = TopologySpec.from_kind(kind, PROF, model, **kw)
    plan = spec.provision(AZURE)
    assert plan_roles(plan) == _legacy_topology_roles(kind, plan)
    # and the spec's static role list covers every provisioned role
    assert set(plan_roles(plan)) <= set(spec.roles)


def test_plan_roles_rejects_unstamped_pools():
    plan = Homogeneous().provision(AZURE, PROF, MODEL)
    with pytest.raises(ValueError, match="no router role"):
        plan_roles(plan)


# --- registry binding parity ---------------------------------------------

def test_registry_homogeneous_kinds_have_no_bindings():
    for kind in ("homo", "two_pool", "fleetopt", "disagg_fleetopt"):
        kw = {"windows": tuple(ladder_windows(3))} \
            if kind == "multipool" else {}
        reg = TopologySpec.from_kind(kind, PROF, MODEL, **kw).registry()
        assert not reg.heterogeneous
        assert reg.default.model is MODEL
        assert reg.default.profile is PROF


def test_registry_semantic_bindings():
    spec = TopologySpec.from_kind("semantic", PROF, MODEL)
    reg = spec.registry()
    assert reg.heterogeneous
    assert reg.for_role("small").model is spec.models["small"]
    assert reg.for_role("small").profile is spec.pool("small").profile
    assert reg.for_role("large").model is MODEL
    assert reg.for_role("large").profile is PROF


def test_registry_moe_dispatch():
    spec = TopologySpec.from_kind("moe_pool", PROF, QWEN3_235B_A22B,
                                  dispatch_ms=2.0)
    reg = spec.registry()
    assert reg.default.dispatch_ms == 2.0
    assert reg.default.profile.roofline.w_ms == \
        PROF.roofline.w_ms + 2.0


# --- satellite 2: construction-time validation ---------------------------

def _pool(role="a", window=4096, admit=math.inf, **kw):
    return PoolSpec(role=role, window=window, profile=PROF, admit=admit,
                    **kw)


def _spec(pools, **kw):
    kw.setdefault("models", {"default": MODEL})
    return TopologySpec(kind="custom", pools=tuple(pools), **kw)


def test_validate_empty_pools():
    with pytest.raises(ValueError, match="at least one PoolSpec"):
        _spec(())


def test_validate_duplicate_roles():
    with pytest.raises(ValueError, match="duplicate pool roles"):
        _spec([_pool("a", 4096, 4096.0), _pool("a", 65536)])


def test_validate_duplicate_names():
    with pytest.raises(ValueError, match="duplicate pool names"):
        _spec([_pool("a", 4096, 4096.0, name="p"),
               _pool("b", 65536, name="p")])


def test_validate_dangling_overflow_edge():
    with pytest.raises(ValueError, match="dangling edge"):
        _spec([_pool("a", 4096, 4096.0, overflow_to="nope"),
               _pool("b", 65536)])


def test_validate_backward_edge():
    with pytest.raises(ValueError, match="points backward"):
        _spec([_pool("a", 4096, 4096.0),
               _pool("b", 65536, escalate_to="a")])


def test_validate_evict_needs_destination():
    with pytest.raises(ValueError, match="no\n?.*overflow_to destination"):
        _spec([_pool("a", 4096, 4096.0, evict_on_overflow=True),
               _pool("b", 65536)])


def test_validate_windows_strictly_ascending():
    with pytest.raises(ValueError, match="strictly ascending"):
        _spec([_pool("a", 65536, 4096.0), _pool("b", 65536)])


def test_validate_admits_strictly_ascending():
    with pytest.raises(ValueError, match="strictly ascending"):
        _spec([_pool("a", 4096, 8192.0), _pool("b", 65536, 8192.0)])


def test_validate_last_admit_infinite():
    with pytest.raises(ValueError, match="admit everything"):
        _spec([_pool("a", 4096, 2048.0), _pool("b", 65536, 65536.0)])


def test_validate_admit_beyond_window():
    with pytest.raises(ValueError, match="exceeds\n?.*serve window"):
        _spec([_pool("a", 4096, 8192.0), _pool("b", 65536)])


def test_validate_no_admitting_pool():
    with pytest.raises(ValueError, match="cannot enter the fleet"):
        _spec([_pool("a", 4096, None)])


def test_validate_unreachable_pool():
    with pytest.raises(ValueError, match="never receive traffic"):
        _spec([_pool("a", 4096, math.inf), _pool("b", 65536, None)])


def test_validate_prefill_needs_handoff():
    with pytest.raises(ValueError, match="handoff_to"):
        _spec([_pool("pf", 4096, math.inf, phase="prefill")])


def test_validate_handoff_phase_consistent():
    with pytest.raises(ValueError, match="phase-consistent"):
        _spec([_pool("a", 4096, math.inf, handoff_to="b"),
               _pool("b", 4096, None)])


def test_validate_handoff_same_window():
    with pytest.raises(ValueError, match="crosses\n?.*window slices"):
        _spec([_pool("pf", 4096, math.inf, phase="prefill",
                     handoff_to="dec"),
               _pool("dec", 8192, None)])


def test_validate_unknown_model_key():
    with pytest.raises(ValueError, match="not in\n?.*spec.models"):
        _spec([_pool("a", 4096, math.inf, model_key="missing")])


def test_validate_misroute_range_and_flip():
    with pytest.raises(ValueError, match=r"misroute_rate must be in"):
        _spec([_pool("a")], misroute_rate=1.5)
    with pytest.raises(ValueError, match="needs a flip"):
        _spec([_pool("a")], misroute_rate=0.1)


def test_validate_flip_roles_and_escalation():
    with pytest.raises(ValueError, match="flip role"):
        _spec([_pool("a", 4096, 4096.0), _pool("b", 65536)],
              flip=("nope", "b"))
    with pytest.raises(ValueError, match="must escalate_to"):
        _spec([_pool("a", 4096, 4096.0), _pool("b", 65536)],
              flip=("a", "b"))


def test_validate_hol_and_dispatch_and_window():
    with pytest.raises(ValueError, match="hol_inflation"):
        _spec([_pool("a", hol_inflation=0.5)])
    with pytest.raises(ValueError, match="dispatch_ms"):
        _spec([_pool("a", dispatch_ms=-1.0)])
    with pytest.raises(ValueError, match="positive token count"):
        _spec([_pool("a", window=0)])
    with pytest.raises(ValueError, match="unknown phase"):
        _spec([_pool("a", phase="warp")])


def test_from_kind_legacy_errors_preserved():
    with pytest.raises(ValueError, match="misroute_rate only applies"):
        TopologySpec.from_kind("fleetopt", PROF, MODEL, misroute_rate=0.1)
    with pytest.raises(ValueError, match="dispatch_ms only applies"):
        TopologySpec.from_kind("homo", PROF, MODEL, dispatch_ms=2.0)
    with pytest.raises(ValueError, match="needs an ascending"):
        TopologySpec.from_kind("multipool", PROF, MODEL)
    with pytest.raises(ValueError, match="strictly ascending"):
        TopologySpec.from_kind("multipool", PROF, MODEL,
                               windows=(8192, 4096))
    with pytest.raises(ValueError, match="collide"):
        TopologySpec.from_kind("multipool", PROF, MODEL,
                               windows=(4096, 4100, 65536))
    with pytest.raises(ValueError, match="gamma must be"):
        TopologySpec.from_kind("multipool", PROF, MODEL,
                               windows=(4096, 65536), gamma=0.5)
    with pytest.raises(ValueError):
        TopologySpec.from_kind("nope", PROF, MODEL)


# --- derived facts -------------------------------------------------------

def test_max_window_subsumes_legacy_long_window():
    assert TopologySpec.from_kind("homo", PROF, MODEL).max_window \
        == LONG_WINDOW
    assert TopologySpec.from_kind(
        "multipool", PROF, MODEL,
        windows=(2048, 8192, 32768)).max_window == 32768
    assert TopologySpec.from_kind(
        "fleetopt", PROF, MODEL, long_window=131072).max_window == 131072


def test_spec_hash_stable_and_sensitive():
    a = TopologySpec.from_kind("fleetopt", PROF, MODEL)
    b = TopologySpec.from_kind("fleetopt", PROF, MODEL)
    assert a.spec_hash == b.spec_hash
    assert len(a.spec_hash) == 12
    for other in (
            TopologySpec.from_kind("fleetopt", PROF, MODEL, b_short=2048),
            TopologySpec.from_kind("fleetopt", PROF, MODEL, gamma=3.0),
            TopologySpec.from_kind("two_pool", PROF, MODEL),
            TopologySpec.from_kind("semantic", PROF, MODEL),
    ):
        assert other.spec_hash != a.spec_hash, other.kind


def test_build_returns_policy_plan_registry():
    spec = TopologySpec.from_kind("fleetopt", PROF, MODEL, b_short=4096)
    policy, plan, registry = spec.build(AZURE)
    assert policy.spec is spec
    assert policy.ladder == [("short", 8192.0), ("long", math.inf)]
    assert plan_roles(plan) == ["short", "long"]
    assert not registry.heterogeneous
