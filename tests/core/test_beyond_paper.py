"""Beyond-paper extensions: multi-pool (K>=3), carbon/cost objectives,
TPU-v5e profile — each implements a paper §10.3 'future work' item."""
import pytest

from repro.core import AGENT, AZURE, H100_LLAMA70B, V5E_LLAMA70B, FleetOpt, \
    Homogeneous
from repro.core.carbon import GRIDS, bill, rank_topologies
from repro.core.modelspec import LLAMA31_70B
from repro.core.multipool import MultiPool, ladder_windows, sweep_pool_counts


def test_three_pools_beat_two_on_dispersed_traffic():
    """§10.3: 'finer-grained topologies could compound further' — confirmed
    on the agent-heavy (dispersed) trace."""
    two = MultiPool(windows=[8192, 65536]).provision(
        AGENT, H100_LLAMA70B, LLAMA31_70B)
    three = MultiPool(windows=[4096, 16384, 65536]).provision(
        AGENT, H100_LLAMA70B, LLAMA31_70B)
    assert three.tok_per_watt > two.tok_per_watt


def test_pool_count_diminishing_returns():
    sweep = sweep_pool_counts(AZURE, H100_LLAMA70B, LLAMA31_70B)
    tpw = dict(sweep)
    assert tpw[2] > tpw[1]                  # the paper's 2-pool gain
    assert tpw[3] >= tpw[2] * 0.95          # K=3 holds or helps
    gain_12 = tpw[2] / tpw[1]
    gain_23 = tpw[3] / tpw[2]
    assert gain_23 < gain_12                # diminishing returns


def test_ladder_windows_dedupes_clamped_rungs():
    """The 2048-floor clamp used to emit duplicate 2K windows at k >= 5
    (dead pools with identical names); the ladder is now deduped and every
    sweep entry reports its *effective* pool count exactly once."""
    assert ladder_windows(3) == [4096, 16384, 65536]
    assert ladder_windows(5) == [2048, 4096, 16384, 65536]  # 5 -> 4 rungs
    ks = [k for k, _ in sweep_pool_counts(AZURE, H100_LLAMA70B, LLAMA31_70B)]
    assert ks == sorted(set(ks)), ks


def test_multipool_rejects_bad_ladders():
    for windows in ([4096, 4096, 65536], [8192, 4096], []):
        with pytest.raises(ValueError):
            MultiPool(windows=windows).provision(AGENT, H100_LLAMA70B,
                                                 LLAMA31_70B)
    with pytest.raises(ValueError):   # overflow headroom below 1 is not one
        MultiPool(windows=[4096, 65536], gamma=0.5).provision(
            AGENT, H100_LLAMA70B, LLAMA31_70B)


def test_carbon_bill():
    rep = FleetOpt(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    b = bill(rep, GRIDS["us-east-mixed"])
    assert b.g_co2_per_mtok > 0
    assert b.usd_rental_per_mtok > b.usd_energy_per_mtok  # rental dominates
    # cleaner grid, same tok/W, less carbon
    b2 = bill(rep, GRIDS["eu-north"])
    assert b2.g_co2_per_mtok < 0.2 * b.g_co2_per_mtok
    assert b2.tok_per_watt == b.tok_per_watt


def test_topology_ranking_is_objective_dependent():
    reps = {
        "homo": Homogeneous().provision(AZURE, H100_LLAMA70B, LLAMA31_70B),
        "fleetopt": FleetOpt(b_short=4096, gamma=2.0).provision(
            AZURE, H100_LLAMA70B, LLAMA31_70B)}
    by_carbon = rank_topologies(reps, GRIDS["us-east-mixed"],
                                "g_co2_per_mtok")
    assert by_carbon[0]["topology"] == "fleetopt"  # efficiency wins carbon


def test_tpu_v5e_profile():
    """The framework's own deployment target obeys the law too."""
    from repro.core import fit_one_over_w
    fit = fit_one_over_w(V5E_LLAMA70B, contexts=(2048, 4096, 8192, 16384))
    assert fit.slope < -0.8
    assert V5E_LLAMA70B.tp == 16
