"""Fleet topology reproduction (paper Table 3 / §4.2 claims) + the
PoolOverride recalibration surface the SLO loop drives."""
import pytest

from repro.core import (AZURE, LMSYS, B200_LLAMA70B_FLEET, H100_LLAMA70B,
                        FleetOpt, Homogeneous, PoolOverride, TwoPool,
                        fleet_tpw_analysis, gain_decomposition,
                        optimize_gamma)
from repro.core.fleet import PoolSizing, apply_overrides
from repro.core.modelspec import LLAMA31_70B

STREAMED = LLAMA31_70B.streamed_params


@pytest.fixture(scope="module")
def azure_grid():
    out = {}
    for gname, prof in (("H100", H100_LLAMA70B), ("B200", B200_LLAMA70B_FLEET)):
        out[gname] = {
            "homo": Homogeneous().provision(AZURE, prof, LLAMA31_70B),
            "pool": TwoPool(b_short=4096).provision(AZURE, prof, LLAMA31_70B),
            "fleetopt": FleetOpt(b_short=4096, gamma=2.0).provision(
                AZURE, prof, LLAMA31_70B),
        }
    return out


def test_azure_h100_column(azure_grid):
    """Paper Table 3 Azure/H100: 141/68/40 instances, 5.58/9.16/14.08 tok/W.
    Fleet internals are under-specified (DESIGN.md §4) — 20% gate."""
    col = azure_grid["H100"]
    assert col["homo"].instances == pytest.approx(141, rel=0.1)
    assert col["pool"].instances == pytest.approx(68, rel=0.15)
    assert col["fleetopt"].instances == pytest.approx(40, rel=0.15)
    assert col["homo"].tok_per_watt == pytest.approx(5.58, rel=0.1)
    assert col["pool"].tok_per_watt == pytest.approx(9.16, rel=0.2)
    assert col["fleetopt"].tok_per_watt == pytest.approx(14.08, rel=0.15)


def test_azure_b200_fleetopt(azure_grid):
    """The headline combined cell: B200+FleetOpt = 23.71 tok/W, 17 inst."""
    rep = azure_grid["B200"]["fleetopt"]
    assert rep.instances == pytest.approx(17, abs=3)
    assert rep.tok_per_watt == pytest.approx(23.71, rel=0.1)


def test_topology_ordering(azure_grid):
    """Homo < Pool < FleetOpt on every GPU and workload (the paper's
    qualitative ranking)."""
    for gen in ("H100", "B200"):
        col = azure_grid[gen]
        assert (col["homo"].tok_per_watt < col["pool"].tok_per_watt
                < col["fleetopt"].tok_per_watt)


def test_combined_gain(azure_grid):
    """§4.2: combined B200+FleetOpt over H100 homo ~ 4.25x (+-15%)."""
    tpw = {g: {t: r.tok_per_watt for t, r in col.items()}
           for g, col in azure_grid.items()}
    g = gain_decomposition(tpw)
    assert g["combined"] == pytest.approx(4.25, rel=0.15)
    # multiplicativity: combined == topo(H100) * gen(fleetopt) by identity;
    # the substantive check is that each lever alone is < 3/4 of combined
    assert g["topo_h100"] < 0.75 * g["combined"]
    assert g["gen_homo"] < 0.75 * g["combined"]


def test_gamma_star_optimal():
    """gamma* = 2 on Azure (paper Table 3), as the smallest window multiple
    whose overflow-migration rate clears the P99 TTFT budget; smaller gamma
    would pack better (n_max ~ 1/window) but violates the SLO."""
    g_star, rep = optimize_gamma(AZURE, H100_LLAMA70B, LLAMA31_70B, 4096)
    assert g_star == 2.0
    assert FleetOpt(b_short=4096, gamma=1.0).mispredict_rate(AZURE) > 5e-5
    assert FleetOpt(b_short=4096, gamma=2.0).mispredict_rate(AZURE) <= 5e-5
    # optimal among SLO-feasible choices
    for g in (3.0, 4.0):
        other = FleetOpt(b_short=4096, gamma=g).provision(
            AZURE, H100_LLAMA70B, LLAMA31_70B)
        assert rep.tok_per_watt >= other.tok_per_watt


def test_lmsys_ordering():
    for prof in (H100_LLAMA70B, B200_LLAMA70B_FLEET):
        h = Homogeneous().provision(LMSYS, prof, LLAMA31_70B)
        f = FleetOpt(b_short=1536, gamma=2.0).provision(LMSYS, prof,
                                                        LLAMA31_70B)
        assert f.tok_per_watt > 1.4 * h.tok_per_watt


def _pool():
    return PoolSizing(name="p", window=65536, profile=H100_LLAMA70B,
                      arrival_rate=100.0, mean_output=300.0,
                      mean_context=4000.0, mean_prompt=1500.0
                      ).size(streamed_params=STREAMED)


def test_recalibrate_only_adds_capacity():
    pool = _pool()
    base, tps = pool.instances, pool.tokens_per_s
    # same MFU: nothing changes
    pool.recalibrate(streamed_params=STREAMED, prefill_mfu=0.8)
    assert pool.instances == base
    # backing the MFU off raises the prefill bound
    pool.recalibrate(streamed_params=STREAMED, prefill_mfu=0.01)
    grown = pool.instances
    assert grown > base and pool.prefill_bound >= grown
    # ...and provision-time throughput adjustments are preserved
    assert pool.tokens_per_s == tps
    # raising the MFU back never shrinks the pool
    pool.recalibrate(streamed_params=STREAMED, prefill_mfu=0.8)
    assert pool.instances == grown
    # instance floor ratchets up, never down
    pool.recalibrate(streamed_params=STREAMED, min_instances=grown + 7)
    assert pool.instances == grown + 7
    pool.recalibrate(streamed_params=STREAMED, min_instances=1)
    assert pool.instances == grown + 7
    # HOL inflation raises the Little's-law decode population
    n_inflight = pool.n_inflight
    pool.recalibrate(streamed_params=STREAMED, hol_inflation=2.0)
    assert pool.n_inflight == pytest.approx(2.0 * n_inflight)
    assert pool.instances >= grown + 7


def test_measured_hol_override_raises_both_closed_form_bounds():
    """The SLO loop now drives PoolOverride.hol_inflation from the
    simulator's measured occupancy inflation (core.slo); the knob must
    feed back into *both* core.fleet sizing bounds — HOL blocking holds
    decode slots longer AND re-queues prefill load."""
    rep = FleetOpt(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    pools = sorted(rep.pools, key=lambda p: p.window)
    long_pool = pools[1]
    dec0, pre0 = long_pool.decode_bound, long_pool.prefill_bound
    n0 = long_pool.n_inflight
    apply_overrides(rep, {"long": PoolOverride(hol_inflation=1.9)},
                    roles=["short", "long"], streamed_params=STREAMED)
    assert long_pool.n_inflight == pytest.approx(1.9 * n0)
    assert long_pool.decode_bound >= dec0
    assert long_pool.prefill_bound >= pre0
    assert long_pool.decode_bound + long_pool.prefill_bound \
        > dec0 + pre0
    assert long_pool.hol_inflation == 1.9


def test_apply_overrides_targets_roles():
    rep = FleetOpt(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    pools = sorted(rep.pools, key=lambda p: p.window)
    before = [p.instances for p in pools]
    apply_overrides(rep, {"long": PoolOverride(min_instances=before[1] + 5)},
                    roles=["short", "long"], streamed_params=STREAMED)
    assert pools[0].instances == before[0]
    assert pools[1].instances == before[1] + 5


def test_analyzer_api():
    """Appendix B: fleet_tpw_analysis accepts any GpuProfile."""
    res = fleet_tpw_analysis(workload="azure-conv", profile=H100_LLAMA70B,
                             b_short=4096)
    assert set(res.reports) == {"homo", "pool", "fleetopt"}
    assert res.gamma_star is not None
    rows = res.table()
    assert rows[0]["vs_baseline"] == "-"
    assert all(r["tok_per_watt"] > 0 for r in rows)
