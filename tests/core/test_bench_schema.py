"""Schema pins for the bench harness contracts CI leans on.

The `--time` timing dump of fleet_sim_bench feeds perf_diff's
wall-clock budget gate, and the Table F gate function feeds the diurnal
acceptance step — both are consumed by code that never imports the
bench, so their shapes are pinned here."""
import importlib.util
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(ROOT, "benchmarks", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


fleet_sim_bench = _load("fleet_sim_bench")
fleet_diurnal_bench = _load("fleet_diurnal_bench")


def test_table_timer_row_schema_is_pinned():
    """Every timing row is exactly {table, config, wall_s,
    sim_s_per_wall_s} — perf_diff.wall_budget_diff keys on all four."""
    cfg = dict(quick=True, n_requests=7, slo_requests=3, seed=0)
    timer = fleet_sim_bench._TableTimer(cfg)
    timer.lap("unconstrained")
    timer.lap("slo")
    timer.total()
    assert [r["table"] for r in timer.rows] \
        == ["unconstrained", "slo", "total"]
    for r in timer.rows:
        assert set(r) == {"table", "config", "wall_s", "sim_s_per_wall_s"}
        assert r["config"] is cfg
        assert isinstance(r["wall_s"], float) and r["wall_s"] >= 0.0
        assert isinstance(r["sim_s_per_wall_s"], float)


def test_timer_laps_are_disjoint_but_total_spans():
    timer = fleet_sim_bench._TableTimer(dict(quick=True))
    timer.lap("a")
    timer.lap("b")
    timer.total()
    a, b, tot = (r["wall_s"] for r in timer.rows)
    assert tot == pytest.approx(a + b, abs=0.05)


# --- Table F gate -------------------------------------------------------

def _cells(tweaks=None):
    rows = []
    for gen, _ in fleet_diurnal_bench.GENERATIONS:
        for kind in fleet_diurnal_bench.KINDS:
            for prov in ("static", "autoscaled"):
                rows.append(dict(generation=gen, topology=kind,
                                 provisioning=prov, tok_per_watt=5.0,
                                 peak_ttft_p99_s=0.3))
    for (gen, kind, prov), kv in (tweaks or {}).items():
        next(r for r in rows if (r["generation"], r["topology"],
                                 r["provisioning"]) == (gen, kind, prov)
             ).update(kv)
    return rows


def test_gate_green_on_healthy_rows():
    assert fleet_diurnal_bench.gate(_cells()) == []


def test_gate_trips_when_autoscaling_loses_tok_per_watt():
    fails = fleet_diurnal_bench.gate(_cells(
        {("H100", "fleetopt", "autoscaled"): dict(tok_per_watt=4.0)}))
    assert len(fails) == 1 and "H100" in fails[0]
    # but a non-fleetopt tok/W dip is reported by the diff step, not
    # this gate (the knob must pay for itself where the headline lives)
    assert fleet_diurnal_bench.gate(_cells(
        {("H100", "homo", "autoscaled"): dict(tok_per_watt=4.0)})) == []


def test_gate_trips_on_peak_ttft_violation_any_cell():
    fails = fleet_diurnal_bench.gate(_cells(
        {("B200", "multipool", "static"): dict(peak_ttft_p99_s=0.7)}))
    assert len(fails) == 1
    assert "B200/multipool/static" in fails[0]


def test_kind_kwargs_cover_kinds():
    assert set(fleet_diurnal_bench.KINDS) \
        == set(fleet_diurnal_bench.KIND_KWARGS)
