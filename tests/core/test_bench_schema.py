"""Schema pins for the bench harness contracts CI leans on.

The `--time` timing dump of fleet_sim_bench feeds perf_diff's
wall-clock budget gate, and the Table F gate function feeds the diurnal
acceptance step — both are consumed by code that never imports the
bench, so their shapes are pinned here."""
import importlib.util
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(ROOT, "benchmarks", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


fleet_sim_bench = _load("fleet_sim_bench")
fleet_diurnal_bench = _load("fleet_diurnal_bench")


def test_table_timer_row_schema_is_pinned():
    """Every timing row is exactly {table, config, wall_s,
    sim_s_per_wall_s} — perf_diff.wall_budget_diff keys on all four."""
    cfg = dict(quick=True, n_requests=7, slo_requests=3, seed=0)
    timer = fleet_sim_bench._TableTimer(cfg)
    timer.lap("unconstrained")
    timer.lap("slo")
    timer.total()
    assert [r["table"] for r in timer.rows] \
        == ["unconstrained", "slo", "total"]
    for r in timer.rows:
        assert set(r) == {"table", "config", "wall_s", "sim_s_per_wall_s"}
        assert r["config"] is cfg
        assert isinstance(r["wall_s"], float) and r["wall_s"] >= 0.0
        assert isinstance(r["sim_s_per_wall_s"], float)


def test_timer_laps_are_disjoint_but_total_spans():
    timer = fleet_sim_bench._TableTimer(dict(quick=True))
    timer.lap("a")
    timer.lap("b")
    timer.total()
    a, b, tot = (r["wall_s"] for r in timer.rows)
    assert tot == pytest.approx(a + b, abs=0.05)


# --- Table F gate -------------------------------------------------------

def _cells(tweaks=None):
    rows = []
    for gen, _ in fleet_diurnal_bench.GENERATIONS:
        for kind in fleet_diurnal_bench.KINDS:
            for prov in ("static", "autoscaled"):
                rows.append(dict(generation=gen, topology=kind,
                                 provisioning=prov, tok_per_watt=5.0,
                                 peak_ttft_p99_s=0.3))
    for (gen, kind, prov), kv in (tweaks or {}).items():
        next(r for r in rows if (r["generation"], r["topology"],
                                 r["provisioning"]) == (gen, kind, prov)
             ).update(kv)
    return rows


def test_gate_green_on_healthy_rows():
    assert fleet_diurnal_bench.gate(_cells()) == []


def test_gate_trips_when_autoscaling_loses_tok_per_watt():
    fails = fleet_diurnal_bench.gate(_cells(
        {("H100", "fleetopt", "autoscaled"): dict(tok_per_watt=4.0)}))
    assert len(fails) == 1 and "H100" in fails[0]
    # but a non-fleetopt tok/W dip is reported by the diff step, not
    # this gate (the knob must pay for itself where the headline lives)
    assert fleet_diurnal_bench.gate(_cells(
        {("H100", "homo", "autoscaled"): dict(tok_per_watt=4.0)})) == []


def test_gate_trips_on_peak_ttft_violation_any_cell():
    fails = fleet_diurnal_bench.gate(_cells(
        {("B200", "multipool", "static"): dict(peak_ttft_p99_s=0.7)}))
    assert len(fails) == 1
    assert "B200/multipool/static" in fails[0]


def test_kind_kwargs_cover_kinds():
    assert set(fleet_diurnal_bench.KINDS) \
        == set(fleet_diurnal_bench.KIND_KWARGS)


# --- FleetScope export schemas ------------------------------------------
# Nightly CI uploads the Perfetto trace + timeline report as artifacts;
# downstream consumers key on these shapes, so version bumps must be
# deliberate (bump the constant AND this pin together).

def test_fleetscope_schema_versions_are_pinned():
    from repro.core import timeline
    assert timeline.TRACE_SCHEMA_VERSION == 1
    assert timeline.TIMELINE_SCHEMA_VERSION == 1
    assert timeline.SERIES_KEYS == (
        "watts", "joules", "decode_j", "prefill_j", "idle_j",
        "handoff_j", "dispatch_j", "tokens", "occupancy", "inflight",
        "queue_depth", "online")
    assert timeline.EVENT_NAMES == (
        "arrive", "route", "admit", "prefill", "first_token", "handoff",
        "escalate", "overflow", "complete")


def test_timeline_json_top_level_shape_is_pinned():
    from repro.core.timeline import MetricsTimeline, empty_series
    doc = MetricsTimeline(t0=0.0, t1=2.0, n_bins=2,
                          pools={"p": empty_series(2)}).to_json()
    assert set(doc) == {"schema_version", "t0", "t1", "n_bins", "bin_s",
                        "meta", "pools", "fleet"}
    assert set(doc["fleet"]) == {"tokens", "joules", "watts", "online",
                                 "cum_tokens", "cum_joules",
                                 "tok_per_watt"}


def test_chrome_trace_doc_shape_is_pinned():
    from repro.core.timeline import chrome_trace_doc, span_event
    doc = chrome_trace_doc([span_event("r0", 0, 0, 0.0, 1.0)],
                           meta={"pools": ["p"]})
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["otherData"]["schema_version"] == 1
    ev = doc["traceEvents"][0]
    assert ev["ph"] == "X" and ev["ts"] == 0.0 and ev["dur"] == 1e6


# --- trace-report gate --------------------------------------------------

fleet_trace_report = _load("fleet_trace_report")


def _trace_rows(err=0.0):
    return [dict(generation="H100", topology="fleetopt",
                 provisioning="autoscaled", reconcile_max_rel_err=err)]


def test_trace_report_gate_keys_on_reconciliation():
    assert fleet_trace_report.gate(_trace_rows(1e-9)) == []
    fails = fleet_trace_report.gate(_trace_rows(5e-3))
    assert len(fails) == 1 and "H100/fleetopt/autoscaled" in fails[0]
