"""Topology search: ladder_spec validation + multipool equivalence,
optimize_topology determinism, incumbent-seeding guarantee, and
spec-hash memoization (novel evaluations only consume budget)."""
import math

import pytest

from repro.core.modelspec import LLAMA31_8B, LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.routing import LONG_WINDOW
from repro.core.slo import SLOSpec
from repro.core.topo_search import (TopologySearchResult, ladder_spec,
                                    optimize_topology)
from repro.core.topospec import TopologySpec
from repro.core.workloads import AZURE

PROF = H100_LLAMA70B
MODEL = LLAMA31_70B
LADDER = (4096, 16384, LONG_WINDOW)


# ---------------------------------------------------------------- ladder_spec

def test_ladder_spec_rejects_non_ascending_windows():
    with pytest.raises(ValueError, match="strictly ascending"):
        ladder_spec((16384, 4096, LONG_WINDOW), [PROF] * 3, MODEL)


def test_ladder_spec_rejects_gamma_below_one():
    with pytest.raises(ValueError, match="gamma"):
        ladder_spec(LADDER, [PROF] * 3, MODEL, gamma=0.5)


def test_ladder_spec_rejects_profile_count_mismatch():
    with pytest.raises(ValueError, match="one profile per rung"):
        ladder_spec(LADDER, [PROF] * 2, MODEL)


def test_ladder_spec_rejects_small_model_without_profile():
    with pytest.raises(ValueError, match="small_profile"):
        ladder_spec(LADDER, [PROF] * 3, MODEL, small_model=LLAMA31_8B)


def test_ladder_spec_matches_multipool_provision():
    """ladder_spec with multipool's windows/gamma provisions the same
    fleet as the legacy kind (same windows, instances, throughput and
    power per rung) — only role names differ."""
    spec = ladder_spec(LADDER, [PROF] * 3, MODEL, gamma=2.0)
    legacy = TopologySpec.from_kind("multipool", PROF, MODEL,
                                    windows=list(LADDER))
    got = spec.provision(AZURE)
    want = legacy.provision(AZURE)
    assert len(got.pools) == len(want.pools)
    for g, w in zip(got.pools, want.pools):
        assert g.window == w.window
        assert g.instances == w.instances
        assert g.tokens_per_s == pytest.approx(w.tokens_per_s)
        assert g.power_w_per_instance == pytest.approx(
            w.power_w_per_instance)
    assert got.tok_per_watt == pytest.approx(want.tok_per_watt)


def test_ladder_spec_disagg_builds_pool_pairs():
    spec = ladder_spec((4096, LONG_WINDOW), [PROF] * 2, MODEL, disagg=True)
    assert spec.accounting == "disagg"
    roles = [p.role for p in spec.pools]
    assert roles == ["prefill-4K", "decode-4K",
                     "prefill-64K", "decode-64K"]
    assert spec.pool("prefill-4K").handoff_to == "decode-4K"
    assert spec.pool("decode-4K").overflow_to == "prefill-64K"
    assert spec.pool("decode-64K").overflow_to is None
    spec.provision(AZURE)   # compiles and sizes without error


def test_ladder_spec_small_first_binds_small_model():
    from repro.core.profiles import computed_profile
    small_prof = computed_profile(LLAMA31_8B, PROF.chip, PROF.power_model,
                                  tp=1)
    spec = ladder_spec(LADDER, [PROF] * 3, MODEL, small_model=LLAMA31_8B,
                       small_profile=small_prof)
    assert spec.pools[0].model_key == "small"
    assert spec.models["small"] is LLAMA31_8B
    assert all(p.model_key == "default" for p in spec.pools[1:])


# ----------------------------------------------------------- optimize_topology

# a 300-request trace has a worse TTFT tail than the bench's 1500+ (the
# p99 lands on a long-prompt prefill whose latency capacity can't fix),
# so the fast tests relax the SLO enough for the incumbent to comply
_FAST = dict(slo=SLOSpec(ttft_p99_s=0.8), n_requests=300, seed=0, budget=4,
             max_rounds=3, trim=False)


def test_search_beats_or_ties_seed_incumbent():
    res = optimize_topology(AZURE, PROF, MODEL, **_FAST)
    assert isinstance(res, TopologySearchResult)
    # history[0] is the seed (multipool K=3) evaluation
    seed_score = res.history[0]["score"]
    assert seed_score is not None          # the incumbent is feasible
    assert res.best_score >= seed_score
    assert res.best_result.compliant
    assert math.isfinite(res.best_score) and res.best_score > 0


def test_search_is_deterministic():
    a = optimize_topology(AZURE, PROF, MODEL, small_model=LLAMA31_8B,
                          **_FAST)
    b = optimize_topology(AZURE, PROF, MODEL, small_model=LLAMA31_8B,
                          **_FAST)
    assert a.best_spec.spec_hash == b.best_spec.spec_hash
    assert a.best_score == b.best_score
    assert [h["spec_hash"] for h in a.history] \
        == [h["spec_hash"] for h in b.history]


def test_search_memoizes_and_respects_budget():
    res = optimize_topology(AZURE, PROF, MODEL, **_FAST)
    assert res.evaluations <= _FAST["budget"]
    hashes = [h["spec_hash"] for h in res.history]
    assert len(hashes) == len(set(hashes))      # only novel specs logged
    assert len(hashes) == res.evaluations


def test_search_row_shape():
    res = optimize_topology(AZURE, PROF, MODEL, **_FAST)
    row = res.row()
    for key in ("workload", "label", "spec_hash", "slo_feasible",
                "measured", "ttft_p99_s", "instances", "compliant",
                "evaluations", "restarts"):
        assert key in row
    assert row["workload"] == AZURE.name
    assert row["spec_hash"] == res.best_spec.spec_hash
