"""tools/lint_invariants.py: the repo itself must scan clean, and the
two rules must actually bite on violating code (a lint that never fires
is a green light taped over a hole)."""
import importlib.util
import os
import pathlib

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
_spec = importlib.util.spec_from_file_location(
    "lint_invariants", os.path.join(ROOT, "tools", "lint_invariants.py"))
lint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(lint)


def test_repo_is_clean():
    assert lint._scan(pathlib.Path(ROOT)) == []


def _tree(tmp_path, rel, text):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return tmp_path


def test_kind_dispatch_outside_topospec_fires(tmp_path):
    root = _tree(tmp_path, "src/repro/serving/rogue.py",
                 'def f(kind):\n    if kind == "fleetopt":\n        pass\n')
    (rel, line, msg), = lint._scan(root)
    assert rel == "src/repro/serving/rogue.py" and line == 2
    assert "from_kind" in msg


def test_block_kind_literals_are_exempt(tmp_path):
    """b.kind == "attn" (repro.models) and shape.kind == "train"
    (repro.launch) are different enums — never flagged."""
    root = _tree(tmp_path, "src/repro/models/blocks.py",
                 'x = 1 if b.kind == "attn" else 2\n'
                 'y = 1 if shape.kind == "train" else 2\n')
    assert lint._scan(root) == []


def test_kind_dispatch_inside_topospec_allowed(tmp_path):
    root = _tree(tmp_path, "src/repro/core/topospec.py",
                 'if kind == "fleetopt":\n    pass\n')
    assert lint._scan(root) == []


def test_mesh_api_outside_compat_fires(tmp_path):
    root = _tree(tmp_path, "src/repro/launch/rogue.py",
                 "from jax.sharding import Mesh, set_mesh\n")
    (rel, _, msg), = lint._scan(root)
    assert rel == "src/repro/launch/rogue.py"
    assert "repro.models.compat" in msg
    # attribute-style access fires too
    root2 = _tree(tmp_path / "b", "src/x.py",
                  "m = jax.sharding.get_abstract_mesh()\n")
    assert len(lint._scan(root2)) == 1


def test_stable_sharding_names_are_fine(tmp_path):
    root = _tree(tmp_path, "src/repro/launch/ok.py",
                 "from jax.sharding import NamedSharding, PartitionSpec\n")
    assert lint._scan(root) == []


def test_importing_shims_from_compat_is_sanctioned(tmp_path):
    root = _tree(tmp_path, "src/repro/models/user.py",
                 "from repro.models.compat import set_mesh\n"
                 "from .compat import get_abstract_mesh\n")
    assert lint._scan(root) == []


def test_print_in_serving_hot_path_fires(tmp_path):
    root = _tree(tmp_path, "src/repro/serving/rogue.py",
                 'def step(self):\n    print("tick", self.t)\n')
    (rel, line, msg), = lint._scan(root)
    assert rel == "src/repro/serving/rogue.py" and line == 2
    assert "TraceRecorder" in msg


def test_print_outside_serving_and_opt_out_are_exempt(tmp_path):
    """Presentation layers print freely; a tagged serving line (e.g. a
    CLI entry point living next to the engines) opts out explicitly.
    Method names merely *ending* in print don't fire."""
    root = _tree(tmp_path, "benchmarks/report.py", 'print("| cell |")\n')
    _tree(root, "src/repro/serving/cli.py",
          'print("summary")  # lint: allow-print\n'
          "self.blueprint(x)\nfoo.print_tree()\n")
    assert lint._scan(root) == []
