"""The 1/W law (paper Table 1, §3.1) — the core claim."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import (B200_LLAMA70B, H100_LLAMA70B, context_sweep,
                        fit_one_over_w)
from repro.core.kvcache import n_max

# Table 1, full reproduction targets.
H100_TABLE1 = [(2048, 512, 598, 35.0), (4096, 256, 593, 17.6),
               (8192, 128, 583, 8.97), (16384, 64, 557, 4.69),
               (32768, 32, 507, 2.58), (65536, 16, 435, 1.50),
               (131072, 8, 369, 0.88)]
B200_TABLE1 = [(2048, 1343, 859, 61.4), (4096, 671, 857, 30.8),
               (8192, 335, 852, 15.5), (16384, 167, 838, 7.87),
               (32768, 83, 805, 4.09), (65536, 41, 735, 2.24),
               (131072, 20, 630, 1.30)]


@pytest.mark.parametrize("profile,table", [
    (H100_LLAMA70B, H100_TABLE1), (B200_LLAMA70B, B200_TABLE1)],
    ids=["H100", "B200"])
def test_table1_full(profile, table):
    rows = context_sweep(profile, [r[0] for r in table])
    for row, (ctx, nm, psat, tpw) in zip(rows, table):
        assert row.n_max == nm, (ctx, row.n_max, nm)
        assert row.p_sat_w == pytest.approx(psat, rel=0.01)
        assert row.tok_per_watt == pytest.approx(tpw, rel=0.02)


def test_nmax_exact_halving():
    """Eq. 3: doubling W halves n_max exactly (power-of-two capacities)."""
    rows = context_sweep(H100_LLAMA70B)
    for a, b in zip(rows, rows[1:]):
        assert a.n_max == 2 * b.n_max


def test_tok_per_watt_halves_per_doubling():
    """The 1/W law: each doubling multiplies tok/W by ~0.5 (drifting up to
    ~0.59 at long context where idle power dominates — paper §3.1)."""
    fit = fit_one_over_w(H100_LLAMA70B)
    assert all(0.48 <= r <= 0.60 for r in fit.halving_ratios)
    assert fit.slope < -0.85
    assert fit.r2 > 0.99


def test_b200_shifts_curve_not_slope():
    """§3.1: B200 lifts the curve 1.5-1.8x but the halving law holds."""
    f_h, f_b = fit_one_over_w(H100_LLAMA70B), fit_one_over_w(B200_LLAMA70B)
    assert abs(f_h.slope - f_b.slope) < 0.1
    h = context_sweep(H100_LLAMA70B)
    b = context_sweep(B200_LLAMA70B)
    gains = [rb.tok_per_watt / rh.tok_per_watt for rh, rb in zip(h, b)]
    assert all(1.45 <= g <= 1.85 for g in gains)
    # §3.1: the advantage narrows at long context (idle-power share grows)
    assert gains[-1] < gains[1]


@settings(max_examples=50, deadline=None)
@given(capacity=st.integers(2 ** 12, 2 ** 24),
       window=st.integers(128, 2 ** 18))
def test_nmax_floor_properties(capacity, window):
    n = n_max(capacity, window)
    assert n >= 1
    if n > 1:
        assert n * window <= capacity
        assert (n + 1) * window > capacity


@settings(max_examples=30, deadline=None)
@given(window=st.sampled_from([2048, 4096, 8192, 16384, 32768]))
def test_law_monotone(window):
    a = H100_LLAMA70B.tok_per_watt_at_window(window)
    b = H100_LLAMA70B.tok_per_watt_at_window(window * 2)
    assert b < a
