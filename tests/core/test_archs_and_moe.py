"""§3.2 architecture effects + §5 design space + per-arch profiles."""
import pytest

from repro.core import computed_profile, context_sweep, fit_one_over_w
from repro.core.hardware import B200, H100
from repro.core.modelspec import (LLAMA31_8B, LLAMA31_70B, LLAMA31_405B,
                                  QWEN3_235B_A22B)
from repro.core.moe import dispatch_sensitivity, moe_profile
from repro.core.power import B200_POWER, H100_POWER
from repro.core.profiles import (GB200_LLAMA70B, H100_LLAMA70B, H200_LLAMA70B,
                                 B200_LLAMA70B)
from repro.core.tokenomics import tok_per_dollar_m
from repro.configs import get_config, list_archs


def test_moe_active_param_advantage():
    """§3.2 mechanism: per-iteration decode time scales with *active*
    weights.  NOTE (documented in EXPERIMENTS.md §Claims): the paper's
    Table-2 cell (37.8 tok/W = 5.1x) divides n_max-throughput by ~P(1)
    power — its 405B row implies 289 W, *below* the 300 W idle floor, so
    the table is internally inconsistent.  The recoverable, physical form
    of the claim is the fixed-concurrency advantage in the
    weight-stream-bound regime, which we gate here."""
    dense = computed_profile(LLAMA31_70B, H100, H100_POWER, tp=8)
    moe = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    # W-stream override: W scales with the *active* fraction (22/235 of a
    # dense 235B; §3.2 quotes 1.6 ms = our 2.11 ms at the paper's 100%-of-
    # peak bandwidth convention vs our calibrated 77.7% efficiency)
    assert (moe.roofline.w_ms / dense.roofline.w_ms
            == pytest.approx(22e9 / 70.6e9, rel=0.02))
    assert moe.roofline.w_ms * 0.777 == pytest.approx(1.64, rel=0.05)
    # advantage at fixed moderate concurrency (same P(b) for both):
    adv8 = moe.tok_per_watt(8, 8192) / dense.tok_per_watt(8, 8192)
    assert 2.0 < adv8 < 5.0
    # the low-concurrency limit approaches the W ratio (~4.1x)
    adv1 = moe.tokens_per_s(1, 8192) / dense.tokens_per_s(1, 8192)
    assert adv1 == pytest.approx(dense.roofline.w_ms / moe.roofline.w_ms,
                                 rel=0.15)
    # at full n_max both are KV-scan-bound and the advantage collapses —
    # the beyond-paper correction to Table 2
    adv_full = (moe.tok_per_watt_at_window(8192)
                / dense.tok_per_watt_at_window(8192))
    assert adv_full < adv8


def test_dispatch_sensitivity_shrinks_advantage():
    """§3.2: 'at 10 ms of dispatch overhead the 5x shrinks to ~1.5x'."""
    pts = dispatch_sensitivity(QWEN3_235B_A22B, LLAMA31_70B, H100,
                               H100_POWER)
    advs = {p.dispatch_ms: p.advantage_vs_dense for p in pts}
    assert advs[0.0] == max(advs.values())          # zero-dispatch = bound
    assert advs[0.0] > 2.0                          # the §3.2 lever exists
    assert advs[10.0] < 0.45 * advs[0.0]            # ...and dispatch eats it
    vals = [p.advantage_vs_dense for p in pts]
    assert vals == sorted(vals, reverse=True)        # monotone decreasing


def test_405b_near_zero_regime():
    """Table 2: 405B on H100 is n_max ~ 1 (weights ~ exhaust VRAM); B200's
    memory lifts it out (24x tok/W jump direction)."""
    h = computed_profile(LLAMA31_405B, H100, H100_POWER, tp=8)
    b = computed_profile(LLAMA31_405B, B200, B200_POWER, tp=8)
    assert h.n_max(8192) == 1
    assert b.n_max(8192) >= 10
    assert (b.tok_per_watt_at_window(8192)
            > 10 * h.tok_per_watt_at_window(8192))


def test_table5_generation_ordering():
    """Table 5 @8K: H200 ~2.1x H100; B200 > H200 in tok/W; GB200-NVL lower
    tok/W than B200 (higher TDP, same compute)."""
    tpw = {n: p.tok_per_watt_at_window(8192)
           for n, p in [("H100", H100_LLAMA70B), ("H200", H200_LLAMA70B),
                        ("B200", B200_LLAMA70B), ("GB200", GB200_LLAMA70B)]}
    assert tpw["H200"] / tpw["H100"] == pytest.approx(2.1, rel=0.3)
    assert tpw["B200"] > tpw["H200"] > tpw["H100"]
    assert tpw["GB200"] < tpw["B200"]
    # Table 5: B200 wins tok/$M too
    assert (tok_per_dollar_m(B200_LLAMA70B, 8192)
            > tok_per_dollar_m(H200_LLAMA70B, 8192)
            > tok_per_dollar_m(H100_LLAMA70B, 8192))


def test_quantization_halves_w():
    """§5.2: fp8 halves weight bytes -> W, roughly doubling tok/W at fixed
    concurrency for weight-streaming-bound models."""
    import dataclasses
    fp16 = computed_profile(LLAMA31_70B, H100, H100_POWER, tp=8)
    fp8_model = dataclasses.replace(LLAMA31_70B, dtype_bytes=1.0)
    fp8 = computed_profile(fp8_model, H100, H100_POWER, tp=8)
    assert fp8.roofline.w_ms == pytest.approx(fp16.roofline.w_ms / 2, rel=0.01)


# ---- the paper's law applied to every assigned architecture --------------

@pytest.mark.parametrize("arch", list_archs())
def test_arch_profile_and_law(arch):
    """Each assigned architecture gets a ComputedProfile; the 1/W law holds
    for attention archs and *vanishes* for attention-free ones (DESIGN §5)."""
    cfg = get_config(arch)
    spec = cfg.analytical_spec()
    prof = computed_profile(spec, H100, H100_POWER,
                            tp=8 if spec.n_params > 2e10 else 1)
    if spec.n_kv_heads == 0:          # rwkv6: no KV growth
        assert spec.kv_bytes_per_token() == 0.0
        return
    fit = fit_one_over_w(prof, contexts=(2048, 4096, 8192, 16384, 32768))
    if cfg.arch_type == "hybrid":
        # Zamba2: only 9 of ~54 blocks hold KV -> far smaller kappa than a
        # same-class full-attention transformer (the law weakens)
        kappa_hybrid = spec.kv_bytes_per_token(tp=8)
        kappa_70b = 2 * 1 * 128 * 2 * 80  # llama-70B TP8-sharded
        assert kappa_hybrid < 0.6 * kappa_70b
    assert fit.slope < -0.5           # halving behaviour present


def test_moe_archs_have_active_override():
    for arch in ("granite-moe-1b-a400m", "grok-1-314b"):
        spec = get_config(arch).analytical_spec()
        assert spec.is_moe
        assert spec.n_active_params < 0.45 * spec.n_params


def test_assigned_param_counts():
    """Config geometry sanity vs the assignment's stated sizes."""
    expect = {"granite-moe-1b-a400m": 1.4e9, "zamba2-2.7b": 2.4e9,
              "whisper-medium": 0.8e9, "h2o-danube-3-4b": 4.0e9,
              "llava-next-34b": 34e9, "granite-3-8b": 8.4e9,
              "yi-6b": 6.1e9, "rwkv6-1.6b": 1.6e9,
              "command-r-plus-104b": 107e9, "grok-1-314b": 316e9}
    for arch, target in expect.items():
        got = get_config(arch).param_count()
        assert got == pytest.approx(target, rel=0.35), (arch, got / 1e9)
