"""Workload reconstructions vs the statistics the paper states."""
import pytest

from repro.core.workloads import AGENT, AZURE, LMSYS


def test_azure_stats():
    # §7: "89% of Azure Conversations requests fit within 4K tokens"
    assert AZURE.frac_total_leq(4096) == pytest.approx(0.89, abs=0.015)
    # reverse-derived from Table 3: fleet tok/s / lambda ~ 325 output tokens
    assert AZURE.mean_output == pytest.approx(325, rel=0.03)


def test_lmsys_stats():
    # Table 3: B_short = 1.5K must actually split the traffic
    frac = LMSYS.frac_total_leq(1536)
    assert 0.6 < frac < 0.95
    assert LMSYS.mean_output == pytest.approx(136, rel=0.06)


def test_agent_stats():
    # §7: "74% of requests fit within 8K tokens ... p99 ~ 32K"
    assert AGENT.frac_total_leq(8192) == pytest.approx(0.74, abs=0.04)
    assert AGENT.quantile_total(0.99) == pytest.approx(32768, rel=0.25)


def test_split_consistency():
    for wl in (AZURE, LMSYS, AGENT):
        s = wl.split_by_total(4096)
        assert s["short"]["frac"] + s["long"]["frac"] == pytest.approx(1.0)
        if s["long"]["frac"]:
            assert s["long"]["mean_context"] > s["short"]["mean_context"]
        total_out = (s["short"]["frac"] * s["short"]["mean_output"]
                     + s["long"]["frac"] * s["long"]["mean_output"])
        assert total_out == pytest.approx(wl.mean_output, rel=0.01)


def test_sampling_deterministic():
    a = AZURE.sample_requests(100, seed=3)
    b = AZURE.sample_requests(100, seed=3)
    assert (a == b).all()
    assert (a > 0).all()
