"""DiurnalProfile: the envelope is exact, not sampled — rate_at /
cumulative / the closed-form inverse must agree with each other to
float precision, and arrival sampling must be a deterministic exact
time-rescaled Poisson process (no thinning noise)."""
import dataclasses

import numpy as np
import pytest

from repro.core.workloads import AZURE_DIURNAL, DiurnalProfile


def test_peak_normalisation_and_swing():
    """The shape is normalised so `peak_rate` is the actual peak."""
    p = DiurnalProfile(peak_rate=400.0, day_s=86400.0)
    t = np.linspace(0.0, p.day_s, 100_001)
    r = p.rate_at(t)
    assert float(r.max()) == pytest.approx(400.0)
    assert p.swing == pytest.approx(float(r.max() / r.min()), rel=1e-9)
    assert p.swing == pytest.approx(5.0)        # Azure-style day/night
    assert p.mean_rate < p.peak_rate


def test_rate_is_periodic():
    p = DiurnalProfile(peak_rate=100.0, day_s=240.0)
    t = np.array([3.0, 117.0, 239.0])
    np.testing.assert_allclose(p.rate_at(t), p.rate_at(t + 240.0),
                               rtol=1e-12)
    np.testing.assert_allclose(p.rate_at(t), p.rate_at(t + 3 * 240.0),
                               rtol=1e-12)


def test_cumulative_matches_numeric_integral():
    p = DiurnalProfile(peak_rate=250.0, day_s=240.0)
    t = np.linspace(0.0, 2.5 * p.day_s, 200_001)   # multi-day incl. wrap
    numeric = np.concatenate(
        [[0.0], np.cumsum((p.rate_at(t[:-1]) + p.rate_at(t[1:])) / 2.0
                          * np.diff(t))])
    np.testing.assert_allclose(p.cumulative(t), numeric, rtol=1e-6,
                               atol=1e-3)


def test_invert_roundtrips_cumulative():
    p = DiurnalProfile(peak_rate=250.0, day_s=240.0)
    t = np.linspace(0.0, p.day_s, 4001)[:-1]
    np.testing.assert_allclose(p._invert(p.cumulative(t)), t, atol=1e-6)


def test_sample_arrivals_deterministic_sorted_and_rate_correct():
    p = DiurnalProfile(peak_rate=200.0, day_s=480.0)
    a = p.sample_arrivals(480.0, seed=7)
    b = p.sample_arrivals(480.0, seed=7)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all()
    assert a[0] >= 0.0 and a[-1] < 480.0
    # total count ~ Lambda(day); 5-sigma band on the Poisson total
    lam = p.cumulative(np.array([480.0]))[0]
    assert abs(len(a) - lam) < 5 * np.sqrt(lam)
    # the empirical trough/peak ratio tracks the envelope's swing
    hour = p.day_s / 24.0
    peak_n = ((a >= 11 * hour) & (a < 13 * hour)).sum() / (2 * hour)
    trough_n = ((a >= 3 * hour) & (a < 5 * hour)).sum() / (2 * hour)
    assert peak_n / max(trough_n, 1e-9) > 3.0


def test_sample_arrivals_different_seed_differs():
    p = DiurnalProfile()
    assert not np.array_equal(p.sample_arrivals(3600.0, seed=0),
                              p.sample_arrivals(3600.0, seed=1))


def test_day_compression_preserves_shape():
    """Compressing the day rescales time, not the envelope."""
    long = DiurnalProfile(peak_rate=100.0, day_s=86400.0)
    short = DiurnalProfile(peak_rate=100.0, day_s=240.0)
    frac = np.linspace(0.0, 1.0, 97)
    np.testing.assert_allclose(long.rate_at(frac * 86400.0),
                               short.rate_at(frac * 240.0), rtol=1e-12)


def test_validation():
    with pytest.raises(ValueError):
        DiurnalProfile(peak_rate=0.0)
    with pytest.raises(ValueError):
        DiurnalProfile(day_s=-1.0)
    with pytest.raises(ValueError):
        DiurnalProfile(shape=(1.0,))
    with pytest.raises(ValueError):
        DiurnalProfile(shape=(1.0, 0.0, 0.5))


def test_module_constant_is_frozen_default():
    assert AZURE_DIURNAL.peak_rate == 1000.0
    assert AZURE_DIURNAL.day_s == 86400.0
    with pytest.raises(dataclasses.FrozenInstanceError):
        AZURE_DIURNAL.peak_rate = 1.0
