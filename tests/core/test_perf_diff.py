"""The perf gate's wall-clock budget must carry an absolute grace floor:
sub-second bench totals are start-up jitter, not simulator regressions,
so a tiny run may never trip (or hide behind) the ratio gate."""
import importlib.util
import json
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
_spec = importlib.util.spec_from_file_location(
    "perf_diff", os.path.join(ROOT, "benchmarks", "perf_diff.py"))
perf_diff = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(perf_diff)


def _dump(tmp_path, name, wall_s):
    cfg = dict(quick=True, n_requests=1000)
    p = tmp_path / name
    p.write_text(json.dumps(dict(timings=[
        dict(table="a", config=cfg, wall_s=wall_s * 0.25),
        dict(table="total", config=cfg, wall_s=wall_s)])))
    return str(p)


def test_wall_floor_forgives_tiny_runs(tmp_path):
    """3x over budget but under the 2 s floor: jitter, not regression."""
    base = _dump(tmp_path, "base.json", 0.3)
    cur = _dump(tmp_path, "cur.json", 0.9)
    rep = perf_diff.wall_budget_diff(base, cur, budget=1.5)
    assert rep["ratio"] == pytest.approx(3.0)
    assert rep["under_floor"] and rep["ok"]


def test_wall_budget_still_trips_above_floor(tmp_path):
    base = _dump(tmp_path, "base.json", 20.0)
    cur = _dump(tmp_path, "cur.json", 40.0)
    rep = perf_diff.wall_budget_diff(base, cur, budget=1.5)
    assert not rep["under_floor"]
    assert not rep["ok"]
    # and an in-budget run above the floor passes on ratio, not grace
    ok = perf_diff.wall_budget_diff(base, _dump(tmp_path, "c2.json", 22.0),
                                    budget=1.5)
    assert ok["ok"] and not ok["under_floor"]


def test_wall_floor_is_tunable(tmp_path):
    base = _dump(tmp_path, "base.json", 0.3)
    cur = _dump(tmp_path, "cur.json", 0.9)
    rep = perf_diff.wall_budget_diff(base, cur, budget=1.5, floor_s=0.5)
    assert not rep["ok"]


# --- cell keying --------------------------------------------------------

def _rows_file(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps({"meta": {}, "rows": rows}))
    return str(p)


def test_provisioning_splits_cells(tmp_path):
    """Table F static vs autoscaled rows share every legacy key field
    and must still land in distinct cells."""
    rows = [dict(table="diurnal", generation="H100", workload="azure-conv",
                 topology="fleetopt", provisioning=p, tok_per_watt=v)
            for p, v in (("static", 4.1), ("autoscaled", 4.4))]
    cells = perf_diff._fleet_cells(_rows_file(tmp_path, "f.json", rows))
    assert len(cells) == 2
    assert any("/static/" in k for k in cells)
    assert any("/autoscaled/" in k for k in cells)


def test_rows_without_provisioning_key_unchanged(tmp_path):
    """Legacy rows get an empty provisioning segment on BOTH sides of a
    diff, so committed steady-state baselines never move."""
    rows = [dict(table="sim", generation="H100", workload="azure-conv",
                 topology="fleetopt", simulated=5.0)]
    path = _rows_file(tmp_path, "f.json", rows)
    (key,) = perf_diff._fleet_cells(path)
    assert key == "sim/H100/azure-conv/fleetopt///:simulated"
    rep = perf_diff.fleet_diff(path, path, tolerance_pct=0.0)
    assert rep["ok"] and len(rep["cells"]) == 1


# --- job-summary markdown emitter ---------------------------------------

def _fleet_rep(tmp_path, base_rows, cur_rows, tol=10.0):
    return perf_diff.fleet_diff(
        _rows_file(tmp_path, "base.json", base_rows),
        _rows_file(tmp_path, "cur.json", cur_rows), tolerance_pct=tol)


def _row(topo, v):
    return dict(table="sim", generation="H100", workload="azure-conv",
                topology=topo, tok_per_watt=v)


def test_summary_markdown_worst_delta_first(tmp_path):
    rep = _fleet_rep(tmp_path,
                     [_row("homo", 2.0), _row("fleetopt", 5.0),
                      _row("multipool", 4.0)],
                     [_row("homo", 2.1), _row("fleetopt", 4.0),
                      _row("multipool", 4.0)])
    md = perf_diff.summary_markdown(rep)
    assert md.startswith("## tok/W regression gate: ❌ FAIL")
    body = [ln for ln in md.splitlines() if ln.startswith("| `")]
    # worst (most negative) delta tops the table, flagged
    assert "fleetopt" in body[0] and "-20.00%" in body[0] and "⚠️" in body[0]
    assert "multipool" in body[1] and "+0.00%" in body[1]
    assert "homo" in body[2]


def test_summary_markdown_missing_cells_and_wall(tmp_path):
    rep = _fleet_rep(tmp_path, [_row("homo", 2.0), _row("fleetopt", 5.0)],
                     [_row("homo", 2.0)])
    wall = dict(ok=False, budget=1.5, baseline_total_s=20.0,
                current_total_s=40.0, ratio=2.0)
    md = perf_diff.summary_markdown(rep, wall, title="fleet_sim gate")
    assert "## fleet_sim gate: ❌ FAIL" in md
    assert "Missing from current run" in md and "fleetopt" in md
    assert "wall-clock budget" in md
    assert "40.0s vs baseline 20.0s" in md and "2.00x" in md


def test_summary_markdown_all_green(tmp_path):
    rows = [_row("homo", 2.0)]
    rep = _fleet_rep(tmp_path, rows, rows)
    md = perf_diff.summary_markdown(rep)
    assert "✅ ok" in md and "⚠️" not in md


def test_emit_step_summary_appends_to_env_file(tmp_path, monkeypatch):
    rows = [_row("homo", 2.0)]
    rep = _fleet_rep(tmp_path, rows, rows)
    out = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(out))
    perf_diff._emit_step_summary(rep, title="first")
    perf_diff._emit_step_summary(rep, title="second")
    text = out.read_text()
    assert "## first: ✅ ok" in text and "## second: ✅ ok" in text
    # and a runner without the env var is a silent no-op
    monkeypatch.delenv("GITHUB_STEP_SUMMARY")
    perf_diff._emit_step_summary(rep)
