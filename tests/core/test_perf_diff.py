"""The perf gate's wall-clock budget must carry an absolute grace floor:
sub-second bench totals are start-up jitter, not simulator regressions,
so a tiny run may never trip (or hide behind) the ratio gate."""
import importlib.util
import json
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
_spec = importlib.util.spec_from_file_location(
    "perf_diff", os.path.join(ROOT, "benchmarks", "perf_diff.py"))
perf_diff = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(perf_diff)


def _dump(tmp_path, name, wall_s):
    cfg = dict(quick=True, n_requests=1000)
    p = tmp_path / name
    p.write_text(json.dumps(dict(timings=[
        dict(table="a", config=cfg, wall_s=wall_s * 0.25),
        dict(table="total", config=cfg, wall_s=wall_s)])))
    return str(p)


def test_wall_floor_forgives_tiny_runs(tmp_path):
    """3x over budget but under the 2 s floor: jitter, not regression."""
    base = _dump(tmp_path, "base.json", 0.3)
    cur = _dump(tmp_path, "cur.json", 0.9)
    rep = perf_diff.wall_budget_diff(base, cur, budget=1.5)
    assert rep["ratio"] == pytest.approx(3.0)
    assert rep["under_floor"] and rep["ok"]


def test_wall_budget_still_trips_above_floor(tmp_path):
    base = _dump(tmp_path, "base.json", 20.0)
    cur = _dump(tmp_path, "cur.json", 40.0)
    rep = perf_diff.wall_budget_diff(base, cur, budget=1.5)
    assert not rep["under_floor"]
    assert not rep["ok"]
    # and an in-budget run above the floor passes on ratio, not grace
    ok = perf_diff.wall_budget_diff(base, _dump(tmp_path, "c2.json", 22.0),
                                    budget=1.5)
    assert ok["ok"] and not ok["under_floor"]


def test_wall_floor_is_tunable(tmp_path):
    base = _dump(tmp_path, "base.json", 0.3)
    cur = _dump(tmp_path, "cur.json", 0.9)
    rep = perf_diff.wall_budget_diff(base, cur, budget=1.5, floor_s=0.5)
    assert not rep["ok"]
