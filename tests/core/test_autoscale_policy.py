"""AutoscalePolicy is declarative config riding the topology IR: the
knob must be hash-neutral when unset (committed baseline cell keys may
never move) and hash-active when set (two fleets that autoscale
differently are different topologies)."""
import dataclasses

import pytest

from repro.core.autoscale import AutoscalePolicy
from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.topospec import TopologySpec


def _spec():
    return TopologySpec.from_kind("fleetopt", H100_LLAMA70B, LLAMA31_70B,
                                  b_short=4096)


def test_spec_hash_pinned_without_autoscale():
    """Regression pin: the hash of a plain from_kind spec predates the
    autoscale field and must never move (it keys committed
    topology_search.json baseline cells)."""
    assert _spec().spec_hash == "73e182db6026"


def test_autoscale_changes_spec_hash_only_when_set():
    base = _spec()
    assert dataclasses.replace(base, autoscale=None).spec_hash \
        == base.spec_hash
    scaled = dataclasses.replace(base, autoscale=AutoscalePolicy())
    assert scaled.spec_hash != base.spec_hash
    # and different policies hash differently
    other = dataclasses.replace(
        base, autoscale=AutoscalePolicy(target_utilization=0.5))
    assert other.spec_hash != scaled.spec_hash


def test_policy_canon_covers_every_field():
    """canon() must include every policy field (a knob missing from the
    canon would let two different policies collide in one spec_hash)."""
    pol = AutoscalePolicy()
    canon = pol.canon()
    for f in dataclasses.fields(pol):
        assert getattr(pol, f.name) in canon, f.name


def test_policy_validation():
    with pytest.raises(ValueError):
        AutoscalePolicy(control_interval_s=0.0)
    with pytest.raises(ValueError):
        AutoscalePolicy(target_utilization=1.2)
    with pytest.raises(ValueError):
        AutoscalePolicy(scaleup_lag_s=-1.0)
    with pytest.raises(ValueError):
        AutoscalePolicy(min_frac=1.5)
    with pytest.raises(ValueError):
        AutoscalePolicy(weight_load_Bps=0.0)
    with pytest.raises(ValueError):
        AutoscalePolicy(spare_instances=-1)
